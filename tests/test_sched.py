"""repro.sched: policy goldens, simulator determinism, back-compat
invariance, and executor stress under every policy."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.core import Executor, Heteroflow, place
from repro.sched import (
    BalancedBins,
    CostModel,
    available_policies,
    build_groups,
    get_scheduler,
    simulate,
)

# unit-rate, zero-latency, infinite-bandwidth model with a kernel-declared
# cost metric: kernel seconds == cost, no pull-byte noise in the goldens
MODEL = CostModel(compute_rate=1.0, h2d_bandwidth=float("inf"),
                  d2d_bandwidth=float("inf"), latency_s=0.0, host_time_s=0.0,
                  cost_fn=lambda n: float(n.state.get("cost", 0.0)))
BINS = ["d0", "d1"]


def _kern(G, name, cost, *deps):
    """Kernel with its own pull (own affinity group) depending on ``deps``."""
    p = G.pull(np.zeros(1), name=f"p_{name}")
    k = G.kernel(lambda own, *d: None, p, *deps, cost=cost, name=name)
    k.succeed(p)
    for d in deps:
        k.succeed(d)
    return k


def _chain():
    G = Heteroflow("chain")
    a = _kern(G, "a", 1.0)
    b = _kern(G, "b", 2.0, a)
    _kern(G, "c", 3.0, b)
    return G


def _fanout():
    G = Heteroflow("fanout")
    root = _kern(G, "root", 1.0)
    for i, c in enumerate((5.0, 3.0, 2.0, 2.0)):
        _kern(G, f"br{i}", c, root)
    return G


def _diamond():
    G = Heteroflow("diamond")
    root = _kern(G, "root", 2.0)
    mids = [_kern(G, f"m{i}", c, root) for i, c in enumerate((4.0, 3.0, 1.0))]
    _kern(G, "join", 2.0, *mids)
    return G


def _score(shape_fn, policy):
    G = shape_fn()
    kwargs = {"cost_model": MODEL} if policy == "heft" else {}
    sched = get_scheduler(policy, **kwargs)
    pl = sched.schedule(G, BINS, MODEL.cost_fn)
    return simulate(G, pl, BINS, cost_model=MODEL)


# ----------------------------------------------------------------------
# golden makespans (hand-computed: chain = serial sum; fanout optimum =
# root + best {5,3,2,2} split onto 2 bins = 1 + 7; diamond optimum =
# 2 + max-branch-split 4 + 2)
# ----------------------------------------------------------------------
GOLDEN = {
    ("chain", "balanced"): 6.0,
    ("chain", "heft"): 6.0,
    ("chain", "round_robin"): 6.0,
    ("chain", "random"): 6.0,
    ("fanout", "balanced"): 8.0,
    ("fanout", "heft"): 8.0,
    ("fanout", "round_robin"): 8.0,
    ("fanout", "random"): 10.0,
    ("diamond", "balanced"): 8.0,
    ("diamond", "heft"): 8.0,
    ("diamond", "round_robin"): 9.0,
    ("diamond", "random"): 9.0,
}
SHAPES = {"chain": _chain, "fanout": _fanout, "diamond": _diamond}


@pytest.mark.parametrize("shape", sorted(SHAPES))
@pytest.mark.parametrize("policy", ["balanced", "heft", "round_robin",
                                    "random"])
def test_golden_makespans(shape, policy):
    rep = _score(SHAPES[shape], policy)
    assert rep.makespan == pytest.approx(GOLDEN[(shape, policy)])


def test_registry_lists_all_policies():
    assert {"balanced", "heft", "round_robin", "random"} <= set(
        available_policies())
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        get_scheduler("nope")


def test_balanced_and_heft_reach_fanout_optimum():
    """On the fan-out shape the LPT/HEFT makespan equals the optimal
    2-bin split, and the random baseline is strictly worse."""
    assert (_score(_fanout, "heft").makespan
            == _score(_fanout, "balanced").makespan
            < _score(_fanout, "random").makespan)


def test_simulator_utilization_and_transfers():
    rep = _score(_fanout, "balanced")
    assert set(rep.utilization) == {0, 1}
    assert all(0.0 < u <= 1.0 for u in rep.utilization.values())
    assert rep.busy[0] + rep.busy[1] == pytest.approx(13.0)  # total work
    # zero-cost transfers in this model, but cross-bin edges are counted
    assert rep.n_transfers > 0 and rep.transfer_seconds == 0.0


def test_simulator_deterministic_under_fixed_seed():
    """Same seed → bit-identical placement and simulation, twice over."""
    from workloads import build_random_dag

    reports = []
    for _ in range(2):
        G, _ = build_random_dag(n_kernels=60, seed=42, with_pushes=False)
        pl = get_scheduler("random", seed=42).schedule(G, BINS, MODEL.cost_fn)
        reports.append(simulate(G, pl, BINS, cost_model=MODEL))
    a, b = reports
    assert a.makespan == b.makespan
    assert a.busy == b.busy
    assert a.n_transfers == b.n_transfers
    # finish times are keyed by node id, which differs between the two
    # graph instances; compare the sorted multiset of times instead
    assert sorted(a.finish_times.values()) == sorted(b.finish_times.values())


# ----------------------------------------------------------------------
# back-compat invariance: the old place() entry point IS BalancedBins
# ----------------------------------------------------------------------
def _legacy_style_graph():
    """The existing placement-test graph: 8 independent kernel∪pull
    groups over 2 bins (test_placement.test_independent_groups_balanced)."""
    G = Heteroflow()
    ks = []
    for _ in range(8):
        p = G.pull(np.zeros(64))
        ks.append(G.kernel(lambda a: a, p))
    return G, ks


def test_balancedbins_matches_legacy_place():
    G1, _ = _legacy_style_graph()
    pl_old = place(G1, BINS)
    G2, _ = _legacy_style_graph()
    pl_new = BalancedBins().schedule(G2, BINS)
    id_map = dict(zip(sorted(pl_old), sorted(pl_new)))
    assert {id_map[i]: b for i, b in pl_old.items()} == pl_new


def test_balancedbins_seed_placement_frozen():
    """Byte-for-byte seed behavior: equal-cost groups alternate
    d0,d1,d0,… in creation order (stable LPT + lowest-index tie-break)."""
    G, ks = _legacy_style_graph()
    pl = place(G, BINS)
    assert [pl[k._node.id] for k in ks] == ["d0", "d1"] * 4


def test_all_policies_keep_affinity_and_pins():
    """Kernels co-placed with source pulls; sharding pins override every
    policy (the invariants Algorithm 1's affinity phase guarantees)."""
    for policy in available_policies():
        G = Heteroflow()
        p1, p2 = G.pull(np.zeros(4)), G.pull(np.zeros(4))
        k = G.kernel(lambda a, b: a, p1, p2)
        pinned_p = G.pull(np.zeros(4), sharding="d1")
        pinned_k = G.kernel(lambda a: a, pinned_p)
        pl = get_scheduler(policy).schedule(G, BINS)
        assert pl[p1._node.id] == pl[p2._node.id] == pl[k._node.id]
        assert pl[pinned_p._node.id] == pl[pinned_k._node.id] == "d1"


def test_groups_first_seen_order():
    G, _ = _legacy_style_graph()
    groups = build_groups(G)
    assert [g.order for g in groups] == list(range(8))
    assert all(len(g.nodes) == 2 for g in groups)  # kernel + its pull


# ----------------------------------------------------------------------
# executor stress: ≥200-node random DAGs under every policy — completion,
# no deadlock, and identical results (placement never changes semantics)
# ----------------------------------------------------------------------
def test_executor_stress_identical_results_across_policies():
    import jax

    from workloads import build_random_dag

    bins = list(jax.devices()) * 2   # two bins, even on a 1-device host
    results = {}
    for policy in available_policies():
        G, outputs = build_random_dag(n_kernels=100, seed=3)
        assert len(G) >= 200, "stress graph must have >= 200 nodes"
        with Executor(num_workers=4, devices=bins, scheduler=policy) as ex:
            assert ex.run(G).result(timeout=120) == 1   # completed, no deadlock
        assert np.isfinite(outputs).all() and (outputs != 0).any()
        results[policy] = outputs.copy()
    base = results.pop("balanced")
    for policy, out in results.items():
        np.testing.assert_allclose(out, base, rtol=0, atol=1e-9,
                                   err_msg=f"policy {policy} changed results")


def test_executor_reports_policy_in_stats():
    import jax
    with Executor(num_workers=1, devices=list(jax.devices()),
                  scheduler="round_robin") as ex:
        G = Heteroflow()
        G.host(lambda: None)
        ex.run(G).result(timeout=30)
        assert ex.stats()["policy"] == "round_robin"
